"""CP-ALS driver behaviour: fit recovery, numerics regressions, and the
fused executor's equivalence with the eager driver (DESIGN.md §11)."""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.cp_als import cp_als, reconstruct_values
from repro.core.cp_als_fused import FUSED_FIT_TOL, FusedCPALS, cp_als_fused
from repro.core.sparse_tensor import SparseTensor, random_sparse_tensor


def _low_rank_sparse(shape, rank, seed=0):
    """Exactly rank-R tensor with EVERY cell stored explicitly (a CP-ALS
    fit target must treat absent cells as true zeros, so a *sampled*
    low-rank tensor is not itself low rank)."""
    rng = np.random.default_rng(seed)
    facs = [rng.random((s, rank)).astype(np.float32) for s in shape]
    grids = np.meshgrid(*[np.arange(s) for s in shape], indexing="ij")
    idx = np.stack([g.ravel() for g in grids], 1).astype(np.int32)
    prod = np.ones((idx.shape[0], rank), np.float32)
    for m, f in enumerate(facs):
        prod *= f[idx[:, m]]
    vals = prod.sum(1).astype(np.float32)
    return SparseTensor(idx, vals, shape)


def test_fit_monotone_and_high_on_low_rank_data():
    t = _low_rank_sparse((20, 15, 12), rank=3, seed=3)
    state = cp_als(t, rank=6, n_iters=40, seed=1)
    # Fit should improve overall and reach a high value on exact-rank data.
    assert state.fits[-1] >= state.fits[0] - 1e-6
    assert state.fit > 0.95, state.fits


def test_reconstruct_values_shape():
    t = random_sparse_tensor((10, 9, 8), nnz=50, seed=0)
    state = cp_als(t, rank=4, n_iters=2)
    vals = reconstruct_values(jnp.asarray(t.indices), state.factors, state.weights)
    assert vals.shape == (t.nnz,)
    assert np.isfinite(np.asarray(vals)).all()


def test_cp_als_with_pallas_backend_matches_ref():
    t = _low_rank_sparse((12, 10, 8), rank=3, seed=5)
    s_ref = cp_als(t, rank=4, n_iters=5, seed=2, impl="ref")
    s_pal = cp_als(t, rank=4, n_iters=5, seed=2, impl="pallas")
    assert abs(s_ref.fit - s_pal.fit) < 1e-3, (s_ref.fit, s_pal.fit)


def test_4mode_als_runs():
    t = random_sparse_tensor((12, 10, 8, 6), nnz=400, seed=9)
    state = cp_als(t, rank=4, n_iters=3)
    assert len(state.factors) == 4
    assert all(np.isfinite(np.asarray(f)).all() for f in state.factors)


# --- numerics regressions ---------------------------------------------------


def test_all_zero_tensor_fit_is_zero_not_nan():
    """||X|| = 0 used to yield sqrt(0)/sqrt(0) = NaN fits that silently
    poisoned the convergence check."""
    t = random_sparse_tensor((10, 8, 6), nnz=40, seed=3)
    t0 = dataclasses.replace(t, values=np.zeros_like(t.values))
    state = cp_als(t0, rank=3, n_iters=2, tol=0.0)
    assert state.fit == 0.0
    assert all(np.isfinite(state.fits)) and all(f == 0.0 for f in state.fits)


def test_cp_als_refuses_empty_tensor():
    empty = SparseTensor(
        np.zeros((0, 3), np.int32), np.zeros((0,), np.float32), (4, 4, 4)
    )
    with pytest.raises(ValueError, match="at least one nonzero"):
        cp_als(empty, rank=2)
    with pytest.raises(ValueError, match="at least one nonzero"):
        cp_als(empty, rank=2, fused=True)
    with pytest.raises(ValueError, match="at least one nonzero"):
        FusedCPALS(empty, 2)


def test_cp_als_dtype_plumbed_mixed_precision():
    """dtype= reaches cp_init and the whole loop runs with reduced-precision
    factors against fp32 values (previously unreachable from cp_als)."""
    t = random_sparse_tensor((14, 12, 10), nnz=200, seed=4)
    state32 = cp_als(t, rank=4, n_iters=3, tol=0.0, seed=1)
    state16 = cp_als(t, rank=4, n_iters=3, tol=0.0, seed=1, dtype=jnp.bfloat16)
    assert all(f.dtype == jnp.bfloat16 for f in state16.factors)
    assert state16.weights.dtype == jnp.bfloat16
    assert all(np.isfinite(state16.fits))
    # Same seeds, same math at different storage precision: trajectories
    # agree loosely (bf16 has ~3 decimal digits).
    assert abs(state16.fit - state32.fit) < 0.1
    # Default dtype is unchanged fp32.
    assert all(f.dtype == jnp.float32 for f in state32.factors)


def test_fused_dtype_plumbed():
    t = random_sparse_tensor((14, 12, 10), nnz=200, seed=4)
    res = cp_als_fused(t, 4, n_iters=2, tol=0.0, dtype=jnp.bfloat16)
    assert all(f.dtype == jnp.bfloat16 for f in res.state.factors)
    assert all(np.isfinite(res.state.fits))


# --- fused executor equivalence (DESIGN.md §11) ------------------------------


@pytest.mark.parametrize("impl", ["ref", "pallas", "sharded"])
def test_fused_matches_eager_fit_trajectory(impl):
    """Same seeds => same trajectories per impl, within the documented
    float-summation tolerance (one fused XLA program may re-associate
    sums the eager per-op dispatch kept separate)."""
    t = random_sparse_tensor((30, 25, 20), nnz=600, seed=0)
    eager = cp_als(t, rank=4, n_iters=4, tol=0.0, seed=2, impl=impl)
    fused = cp_als(t, rank=4, n_iters=4, tol=0.0, seed=2, impl=impl, fused=True)
    assert len(fused.fits) == len(eager.fits)
    np.testing.assert_allclose(fused.fits, eager.fits, atol=FUSED_FIT_TOL)
    for fe, ff in zip(eager.factors, fused.factors):
        np.testing.assert_allclose(np.asarray(ff), np.asarray(fe), atol=1e-3)


def test_fused_fit_every_cadence_same_trajectory():
    """fit_every only changes WHEN the host syncs, never the math: the
    trajectory is identical, the sync count drops."""
    t = random_sparse_tensor((20, 16, 12), nnz=300, seed=6)
    r1 = cp_als_fused(t, 4, n_iters=5, tol=0.0, seed=1, fit_every=1)
    r2 = cp_als_fused(t, 4, n_iters=5, tol=0.0, seed=1, fit_every=2)
    np.testing.assert_allclose(r1.fits, r2.fits, atol=1e-6)
    assert r1.sync_count == 5
    assert r2.sync_count == 3  # ceil(5 / 2)


def test_fused_early_stop_matches_eager_at_unit_cadence():
    t = _low_rank_sparse((12, 10, 8), rank=2, seed=1)
    eager = cp_als(t, rank=3, n_iters=30, tol=1e-4, seed=0)
    fused = cp_als(t, rank=3, n_iters=30, tol=1e-4, seed=0, fused=True)
    assert fused.iters == eager.iters
    np.testing.assert_allclose(fused.fits, eager.fits, atol=FUSED_FIT_TOL)


def test_fused_multi_restart_shapes_and_selection():
    t = random_sparse_tensor((18, 14, 10), nnz=250, seed=8)
    res = cp_als_fused(t, 4, n_iters=3, tol=0.0, seed=7, restarts=3)
    assert res.fits.shape == (3, 3)
    assert res.seeds == (7, 8, 9)
    assert res.best_restart == int(np.argmax(res.fits[:, -1]))
    assert res.state.fit == max(res.final_fits)
    # The vmap batch reproduces the single-seed runs exactly (same
    # cp_init draws, same math, batched along the restart axis).
    singles = [
        cp_als_fused(t, 4, n_iters=3, tol=0.0, seed=s).state.fit for s in res.seeds
    ]
    np.testing.assert_allclose(res.final_fits, singles, atol=FUSED_FIT_TOL)


def test_fused_executor_reuse_and_restart_batch_consistency():
    t = random_sparse_tensor((18, 14, 10), nnz=250, seed=8)
    executor = FusedCPALS(t, 4)
    a = executor.run(n_iters=2, tol=0.0, seed=0)
    b = executor.run(n_iters=2, tol=0.0, seed=0)  # reused buffers + jit cache
    np.testing.assert_array_equal(a.fits, b.fits)
    batched = executor.run(n_iters=2, tol=0.0, seeds=(0, 5))
    np.testing.assert_allclose(batched.fits[0], a.fits[0], atol=FUSED_FIT_TOL)


def test_fused_rejects_bad_args():
    t = random_sparse_tensor((10, 8, 6), nnz=50, seed=0)
    with pytest.raises(ValueError, match="unknown impl"):
        FusedCPALS(t, 2, impl="nope")
    with pytest.raises(ValueError, match="restarts"):
        cp_als(t, rank=2, restarts=4)  # batching requires fused=True
    with pytest.raises(ValueError, match="fit_every"):
        cp_als(t, rank=2, fit_every=3)  # sync cadence requires fused=True
    with pytest.raises(ValueError, match="mttkrp_fn"):
        cp_als(t, rank=2, fused=True, mttkrp_fn=lambda t, f, m: None)
    ex = FusedCPALS(t, 2)
    with pytest.raises(ValueError, match="fit_every"):
        ex.run(fit_every=0)
    with pytest.raises(ValueError, match="n_iters"):
        ex.run(n_iters=0)

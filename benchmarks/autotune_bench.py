"""Closed-loop MTTKRP tile-autotuning benchmark (DESIGN.md §13).

One cell per scaled FROSTT tensor.  Each cell:

  * times the default tile config ``(256, 256, lex)`` on the interpret
    backend (mode 0 only — the emulator is the slow side) and on the
    platform's compiled backend (the XLA fallback on CPU);
  * runs the DSE autotuner over the full tune space on the compiled
    backend, summing fenced per-mode medians;
  * checks compiled-vs-ref numerical parity on every mode.

Gate fields per cell (the driver aggregates them):

  * ``compiled_faster`` — compiled default strictly beats interpret;
  * ``tuned_ok``        — tuned total <= default total (structural: the
                          default config is always in the tune space);
  * ``parity_ok``       — max rel err vs the jnp oracle <= PARITY_RTOL.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mttkrp import mttkrp_ref
from repro.data.synthetic_tensors import make_frostt_like
from repro.dse.autotune import (
    DEFAULT_TILE_CONFIG,
    Autotuner,
    measure_config,
    measured_vs_modeled,
)
from repro.kernels.mttkrp.ops import get_plan, mttkrp_from_plan, resolve_backend

# Compiled kernels accumulate in f32 like the oracle; the tolerance
# covers reassociated summation order across tile boundaries.
PARITY_RTOL = 2e-5


def make_factors(tensor, rank: int, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), tensor.nmodes)
    return [
        jax.random.normal(k, (s, rank), jnp.float32)
        for k, s in zip(keys, tensor.shape)
    ]


def parity_max_rel_err(tensor, factors, config, backend: str) -> float:
    """Max relative error of the compiled kernel vs the jnp oracle, all modes."""
    worst = 0.0
    for mode in range(tensor.nmodes):
        plan = get_plan(
            tensor,
            mode,
            tile_nnz=config.tile_nnz,
            rows_per_block=config.rows_per_block,
        )
        got = np.asarray(mttkrp_from_plan(plan, factors, backend=backend))
        want = np.asarray(mttkrp_ref(tensor, factors, mode))
        err = float(np.abs(got - want).max() / (np.abs(want).max() + 1e-9))
        worst = max(worst, err)
    return worst


def bench_cell(
    name: str,
    scale: float,
    *,
    rank: int,
    tuner: Autotuner,
    reps: int = 3,
    interpret_reps: int = 1,
    seed: int = 0,
) -> dict:
    """Measure one (tensor, backend) autotuning cell; see module docstring."""
    tensor = make_frostt_like(name, scale=scale, seed=seed)
    factors = make_factors(tensor, rank, seed=seed)
    backend = resolve_backend(None)

    # Interpret baseline, mode 0 at the default config.  The emulator's
    # per-tile overhead makes full-mode sweeps prohibitive; one mode is
    # enough to establish the compiled-vs-interpret ordering.
    interpret_s = measure_config(
        tensor, factors, 0, DEFAULT_TILE_CONFIG, backend="interpret",
        reps=interpret_reps,
    )
    compiled_mode0_s = measure_config(
        tensor, factors, 0, DEFAULT_TILE_CONFIG, backend=backend, reps=reps
    )

    result = tuner.tune(tensor, rank, seed=seed)
    parity = parity_max_rel_err(tensor, factors, result.best, backend)

    cell = {
        "tensor": f"{name}@{scale:g}",
        "dims": list(tensor.shape),
        "nnz": tensor.nnz,
        "rank": rank,
        "backend": backend,
        "signature": str(result.signature),
        "interpret_mode0_s": interpret_s,
        "compiled_mode0_s": compiled_mode0_s,
        "interpret_speedup": interpret_s / compiled_mode0_s,
        "default_config": DEFAULT_TILE_CONFIG.label,
        "default_s": result.default_s,
        "best_config": result.best.label,
        "best_s": result.best_s,
        "speedup_vs_default": result.speedup_vs_default,
        "parity_max_rel_err": parity,
        "timings": {cfg.label: s for cfg, s in result.timings.items()},
        "compiled_faster": compiled_mode0_s < interpret_s,
        "tuned_ok": result.best_s <= result.default_s,
        "parity_ok": parity <= PARITY_RTOL,
    }
    cell["measured_vs_modeled"] = measured_vs_modeled(
        tensor, result, rank=rank, name=f"{name}@{scale:g}"
    )
    return cell


def run() -> list[tuple[str, float, str]]:
    """CSV rows for the benchmarks.run aggregator (smallest cell only)."""
    tuner = Autotuner(reps=2)
    cell = bench_cell("NELL-2", 5e-5, rank=16, tuner=tuner, reps=2)
    return [
        ("autotune.interpret_mode0_us", round(cell["interpret_mode0_s"] * 1e6, 1),
         "default config, emulator"),
        ("autotune.compiled_mode0_us", round(cell["compiled_mode0_s"] * 1e6, 1),
         cell["backend"]),
        ("autotune.best_config", 0.0, cell["best_config"]),
        ("autotune.speedup_vs_default", round(cell["speedup_vs_default"], 3), ""),
        ("autotune.parity_max_rel_err", cell["parity_max_rel_err"], "vs oracle"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))

"""Subprocess worker for multi-device measured runs.

``python -m repro.experiments.worker`` reads a JSON payload on stdin
(name/scale/seed identify the tensor deterministically; impl is always
``sharded`` today), runs the instrumented CP-ALS sweep on the forced
host-device mesh (the parent sets ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` BEFORE this process first initializes XLA), and prints
the ``MeasuredRun`` as one JSON line on stdout.  Kept dependency-free on
the engine so a failed import there cannot mask a worker error.
"""

from __future__ import annotations

import json
import sys


def main() -> int:
    payload = json.loads(sys.stdin.read())

    import jax

    expected = int(payload.get("devices", 8))
    if jax.device_count() != expected:
        print(
            f"worker: expected {expected} devices, got {jax.device_count()} "
            "(XLA_FLAGS must be set before first jax init)",
            file=sys.stderr,
        )
        return 2

    from repro.data.synthetic_tensors import make_frostt_like
    from repro.experiments.measure import measure_cp_als

    tensor = make_frostt_like(
        payload["name"], scale=payload["scale"], seed=payload["seed"]
    )
    ordering = payload.get("ordering")
    # Deterministic re-application of the engine-side degree relabeling
    # (degree_reorder is a pure function of the tensor).
    from repro.reorder import prepare_execution

    tensor, _ = prepare_execution(tensor, ordering)
    run = measure_cp_als(
        tensor,
        name=payload["tensor_name"],
        rank=payload["rank"],
        n_iters=payload["n_iters"],
        impl="sharded",
        seed=payload["seed"],
        scheme=payload.get("scheme", "mode_ordered"),
        ordering=ordering,
        backend=payload.get("backend"),
        # cost_analysis lowers the ref closure as a stand-in; the sharded
        # shard_map path is traced eagerly and has no single compiled HLO.
        cost_analysis=False,
        # Fused-executor timing (DESIGN.md §11) runs HERE so the fused
        # sharded path sees the same forced-host-device mesh.
        fused=bool(payload.get("fused", False)),
        fit_every=int(payload.get("fit_every", 1)),
    )
    print(json.dumps(run.to_dict()))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Closed-loop tile autotuner (repro.dse.autotune, DESIGN.md §13)."""

import numpy as np
import pytest

from repro.core.cp_als import cp_init
from repro.core.sparse_tensor import random_sparse_tensor
from repro.dse.autotune import (
    DEFAULT_TILE_CONFIG,
    Autotuner,
    TileConfig,
    TuneSpace,
    WallTimeMemo,
    measure_config,
    measured_vs_modeled,
)
from repro.serve import geometry_signature

# Two non-default configs keeps every tune() in the suite at 3 configs x
# 3 modes x 1 rep — fast enough to run the real measurement loop.
SMALL_SPACE = TuneSpace(tile_nnz=(128,), rows_per_block=(64, 128), orderings=("lex",))


def _tensor(seed=0, nnz=400):
    return random_sparse_tensor((37, 29, 23), nnz=nnz, seed=seed)


def test_tileconfig_validation_and_label():
    assert TileConfig(128, 64, "lex").label == "(128,64,lex)"
    with pytest.raises(ValueError, match="tile_nnz"):
        TileConfig(0, 64, "lex")
    with pytest.raises(ValueError, match="rows_per_block"):
        TileConfig(128, -1, "lex")
    with pytest.raises(ValueError, match="unknown ordering"):
        TileConfig(128, 64, "zigzag")


def test_tunespace_always_contains_default_first():
    for space in (TuneSpace(), SMALL_SPACE, TuneSpace(tile_nnz=(), rows_per_block=())):
        cfgs = space.configs()
        assert cfgs[0] == DEFAULT_TILE_CONFIG
        assert len(cfgs) == len(set(cfgs))  # no duplicates


def test_walltime_memo_counters():
    memo = WallTimeMemo()
    sig = geometry_signature((8, 8, 8), 64, 4)
    key = memo.key(sig, 0, DEFAULT_TILE_CONFIG, "xla", 1)
    assert memo.lookup(key) is None
    assert (memo.hits, memo.misses) == (0, 1)
    memo.store(key, 0.5)
    assert memo.lookup(key) == 0.5
    assert (memo.hits, memo.misses, len(memo)) == (1, 1, 1)


def test_walltime_memo_keys_by_reps():
    # Regression: the memo once ignored the measurement protocol, so a
    # reps=20 request silently got a reps=1 median back.
    memo = WallTimeMemo()
    sig = geometry_signature((8, 8, 8), 64, 4)
    memo.store(memo.key(sig, 0, DEFAULT_TILE_CONFIG, "xla", 1), 0.5)
    assert memo.lookup(memo.key(sig, 0, DEFAULT_TILE_CONFIG, "xla", 20)) is None


def test_measure_config_positive_and_plan_cached():
    t = _tensor()
    facs = cp_init(t, 8, seed=0)
    s = measure_config(t, facs, 0, DEFAULT_TILE_CONFIG, backend="xla", reps=1)
    assert s > 0.0


def test_tune_selects_argmin_and_caches_by_band():
    tuner = Autotuner(SMALL_SPACE, reps=1)
    t = _tensor()
    result = tuner.tune(t, 8)
    assert set(result.timings) == set(SMALL_SPACE.configs())
    assert result.best_s == min(result.timings.values())
    # structural gate: the default is in the swept set, so tuned <= default
    assert result.best_s <= result.default_s
    assert result.speedup_vs_default >= 1.0

    # Full-mode tune records its coverage.
    assert result.modes == tuple(range(t.nmodes))

    # Same band -> cached result object, no new measurements.
    misses_after_first = tuner.memo.misses
    assert tuner.tune(t, 8) is result
    assert tuner.memo.misses == misses_after_first

    # force=True re-measures: it bypasses BOTH the result cache and the
    # wall-time memo (a forced re-tune answered from stale measurements
    # isn't a re-tune), overwriting memo cells with fresh numbers.
    hits_before = tuner.memo.hits
    memo_cells = len(tuner.memo)
    forced = tuner.tune(t, 8, force=True)
    assert forced is not result
    assert set(forced.timings) == set(result.timings)
    assert tuner.memo.hits == hits_before  # no memo answers on force
    assert len(tuner.memo) == memo_cells  # same cells, re-stored

    # A geometrically similar tensor lands in the same band: answered from
    # the cache (the forced re-tune replaced the stored result object).
    t2 = _tensor(seed=5, nnz=410)
    assert tuner.signature_of(t2, 8) == result.signature
    assert tuner.tune(t2, 8) is forced


def test_tune_partial_modes_never_enters_band_cache():
    # Regression: a modes=(0,) tune used to be cached under the band key,
    # so every later full-band config_for answered a mode-0-only argmin.
    tuner = Autotuner(SMALL_SPACE, reps=1)
    t = _tensor()
    partial = tuner.tune(t, 8, modes=(0,))
    assert partial.modes == (0,)
    assert tuner.results == {}  # not a band answer
    assert tuner.config_for(t, 8) == DEFAULT_TILE_CONFIG  # still untuned
    # A subsequent full tune reuses the mode-0 measurements from the memo
    # but measures the remaining modes and DOES enter the band cache.
    misses_before = tuner.memo.misses
    full = tuner.tune(t, 8)
    assert full.modes == tuple(range(t.nmodes))
    assert tuner.results[full.signature] is full
    assert tuner.memo.misses > misses_before  # modes 1..n were measured
    assert "modes" in full.to_dict()


def test_config_for_answers_cheaply_on_miss():
    tuner = Autotuner(SMALL_SPACE, reps=1)
    t = _tensor()
    # Untuned band: the default config, with zero measurements taken.
    assert tuner.config_for(t, 8) == DEFAULT_TILE_CONFIG
    assert len(tuner.memo) == 0
    best = tuner.tune(t, 8).best
    assert tuner.config_for(t, 8) == best


def test_config_for_tune_on_miss():
    tuner = Autotuner(SMALL_SPACE, reps=1, tune_on_miss=True)
    t = _tensor()
    cfg = tuner.config_for(t, 8)
    assert tuner.results  # the miss triggered a real tune
    assert cfg == next(iter(tuner.results.values())).best


def test_tuner_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend='hexagon'"):
        Autotuner(SMALL_SPACE, backend="hexagon")


def test_geometry_signature_tile_align():
    base = geometry_signature((100, 50, 30), 1000, 16)
    aligned = geometry_signature((100, 50, 30), 1000, 16, tile_align=384)
    assert base.nnz_pad == 1024  # next pow2
    assert aligned.nnz_pad == 1152  # rounded up to a multiple of 384
    assert aligned.nnz_pad % 384 == 0
    assert aligned.dims == base.dims and aligned.rank_pad == base.rank_pad
    # pow2 tiles divide the pow2 band: alignment is then a no-op
    assert geometry_signature((100, 50, 30), 1000, 16, tile_align=256) == base
    with pytest.raises(ValueError, match="tile_align"):
        geometry_signature((100, 50, 30), 1000, 16, tile_align=0)


def test_serve_buckets_align_to_tuned_tile():
    """The service's default signature consults the duck-typed autotuner
    and aligns the bucket's padded nonzero stream to the tuned tile."""
    from repro.serve import DecompositionService
    from repro.serve.service import DecompRequest

    class StubTuner:
        def config_for(self, tensor, rank):
            return TileConfig(tile_nnz=384, rows_per_block=64)

    t = _tensor(nnz=1000)
    req = DecompRequest("r0", t, rank=8, n_iters=2)
    plain = DecompositionService().signature_fn(req)
    tuned = DecompositionService(autotuner=StubTuner()).signature_fn(req)
    assert plain.nnz_pad % 384 != 0  # the alignment is not vacuous
    assert tuned.nnz_pad % 384 == 0
    assert tuned.nnz_pad >= plain.nnz_pad


def test_measured_vs_modeled_huge_dims_density():
    """Regression: the ad-hoc characteristics record computed its dense
    volume with np.prod over int64, which wraps negative once the shape
    product passes 2**63 — shapes well within FROSTT range (NELL-1-like
    dims at 10**8-10**9 nnz).  math.prod over Python ints is exact;
    FrosttTensor now rejects the garbage density at construction."""
    from repro.dse.autotune import TuneResult

    t = _tensor(nnz=300)
    # Same indices, astronomically larger claimed shape: the dense volume
    # 2**63 + 2**42 wraps negative in int64.
    big = type(t)(
        indices=t.indices, values=t.values, shape=(2**21, 2**21, 2**21 + 1)
    )
    assert np.prod([int(d) for d in big.shape]) < 0  # the overflow is real
    result = TuneResult(
        signature=Autotuner.signature_of(big, 8),
        backend="xla",
        best=DEFAULT_TILE_CONFIG,
        timings={DEFAULT_TILE_CONFIG: 1e-3},
        modes=(0, 1, 2),
    )
    rows = measured_vs_modeled(big, result, rank=8, name="huge")
    assert len(rows) == 1
    assert np.isfinite(rows[0]["modeled_s"]) and rows[0]["modeled_s"] > 0.0


def test_measured_vs_modeled_rows():
    tuner = Autotuner(SMALL_SPACE, reps=1)
    t = _tensor()
    result = tuner.tune(t, 8)
    rows = measured_vs_modeled(t, result, rank=8, name="unit")
    assert len(rows) == len(SMALL_SPACE.configs())
    assert sum(r["best"] for r in rows) == 1
    for r in rows:
        assert r["measured_s"] > 0.0
        assert np.isfinite(r["modeled_s"]) and r["modeled_s"] > 0.0
    # The analytic model prices the ordering axis only: one modeled value
    # per ordering, shared by every tile geometry under it.
    assert len({r["modeled_s"] for r in rows if r["ordering"] == "lex"}) == 1

"""True-positive fixture for shared-state-safety: bare dict, request-time writes."""

_RESULTS: dict = {}
_LOG = []


def record(key, value):
    _RESULTS[key] = value  # item assignment on module state


def push(item):
    _LOG.append(item)  # mutating method on module state

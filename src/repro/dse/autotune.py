"""Closed-loop tile autotuning for the compiled MTTKRP paths (DESIGN.md §13).

PRs 1–2 built an *analytic* design-space explorer: every configuration is
priced by the paper's closed-form memory model.  This module closes the
loop the way the PMC paper (arXiv 2207.08298) closes it for controller
parameters: the plan-geometry knobs that actually exist in our kernels —
``(tile_nnz, rows_per_block, ordering)`` — are swept with *measured*
fenced wall time on the backend-dispatched compiled path
(``repro.kernels.mttkrp.ops.resolve_backend``), the winner is cached by
padded geometry band, and the measurements feed back into the DSE
evaluator so modeled and measured seconds sit side by side in one table.

Three pieces:

  * ``TileConfig`` / ``TuneSpace`` — the swept knob grid.  The default
    config ``(256, 256, "lex")`` is always a member, so the selected
    winner is ≤ the default *by construction under the shared
    measurement protocol* (argmin over a set containing the default).
  * ``WallTimeMemo`` — a ``HitRateCache``-style memo (hits/misses
    counters, keyed store) of per-(signature, mode, config, backend)
    median wall times, so re-tuning a tensor that lands in an
    already-tuned band measures nothing.
  * ``Autotuner`` — tunes per tensor, keyed by
    ``repro.serve.geometry_signature`` with ``n_iters=0`` — the SAME
    power-of-two banding the serving layer buckets on, so one tuned
    band covers every request the service would batch together.
    ``config_for`` is the duck-typed hook ``DecompositionService``
    consumes (the serve layer never imports this package).

``measured_vs_modeled`` prices the tuner's per-ordering measurements
through ``evaluate_sweep``'s exact-trace method on an ad-hoc
characteristics record, returning rows with both numbers per config.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Mapping, Sequence

import jax
import numpy as np

from repro.core.memory_tech import O_SRAM, MemoryTechSpec
from repro.data.frostt import FrosttTensor
from repro.dse.evaluator import evaluate_sweep
from repro.dse.sweep import SweepPoint
from repro.reorder import ORDERINGS
from repro.serve.service import BucketSignature, geometry_signature

__all__ = [
    "TileConfig",
    "DEFAULT_TILE_CONFIG",
    "TuneSpace",
    "WallTimeMemo",
    "TuneResult",
    "Autotuner",
    "measure_config",
    "measured_vs_modeled",
]


@dataclasses.dataclass(frozen=True, order=True)
class TileConfig:
    """One point of the kernel plan-geometry space."""

    tile_nnz: int = 256
    rows_per_block: int = 256
    ordering: str = "lex"

    def __post_init__(self):
        if self.tile_nnz < 1:
            raise ValueError(f"tile_nnz must be >= 1, got {self.tile_nnz}")
        if self.rows_per_block < 1:
            raise ValueError(
                f"rows_per_block must be >= 1, got {self.rows_per_block}"
            )
        if self.ordering not in ORDERINGS:
            raise ValueError(
                f"unknown ordering {self.ordering!r}; known: {list(ORDERINGS)}"
            )

    @property
    def label(self) -> str:
        return f"({self.tile_nnz},{self.rows_per_block},{self.ordering})"


#: The historical fixed plan geometry every pre-autotuner call site used.
DEFAULT_TILE_CONFIG = TileConfig(256, 256, "lex")


@dataclasses.dataclass(frozen=True)
class TuneSpace:
    """The swept grid.  ``configs()`` always contains the default config,
    which is what makes the bench gate "tuned ≤ default" a structural
    property rather than a hope."""

    tile_nnz: tuple[int, ...] = (128, 256, 512)
    rows_per_block: tuple[int, ...] = (64, 256, 512)
    orderings: tuple[str, ...] = ("lex",)

    def configs(self) -> list[TileConfig]:
        out = [DEFAULT_TILE_CONFIG]
        for o in self.orderings:
            for t in self.tile_nnz:
                for r in self.rows_per_block:
                    cfg = TileConfig(t, r, o)
                    if cfg not in out:
                        out.append(cfg)
        return out


class WallTimeMemo:
    """Measured-seconds memo in the mold of ``dse.evaluator.HitRateCache``:
    a keyed store plus hits/misses counters so tests and bench artifacts
    can verify the tuner never re-measures a (band, mode, config) cell."""

    def __init__(self) -> None:
        self._store: dict[tuple, float] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    @staticmethod
    def key(
        signature: BucketSignature,
        mode: int,
        config: TileConfig,
        backend: str,
        reps: int,
    ) -> tuple:
        # ``reps`` is part of the measurement protocol, not a detail: a
        # median over 3 fenced calls and one over 20 are different
        # estimators, and a memo that conflates them answers reps=20
        # requests with reps=3 numbers.
        return (signature, mode, config, backend, reps)

    def lookup(self, key: tuple) -> float | None:
        if key in self._store:
            self.hits += 1
            return self._store[key]
        self.misses += 1
        return None

    def store(self, key: tuple, seconds: float) -> float:
        self._store[key] = float(seconds)
        return self._store[key]


def measure_config(
    tensor,
    factors: Sequence[jax.Array],
    mode: int,
    config: TileConfig,
    *,
    backend: str | None = None,
    reps: int = 3,
) -> float:
    """Fenced median wall seconds of one mode's MTTKRP under ``config``.

    One untimed warmup call absorbs plan build + trace/compile; the
    median of ``reps`` subsequent ``block_until_ready``-fenced calls is
    the steady-state number — the same protocol
    ``experiments.measure.measure_cp_als`` uses for its ``steady_s``.
    """
    from repro.kernels.mttkrp.ops import get_plan, mttkrp_from_plan

    plan = get_plan(
        tensor,
        mode,
        tile_nnz=config.tile_nnz,
        rows_per_block=config.rows_per_block,
        ordering=config.ordering,
    )
    jax.block_until_ready(mttkrp_from_plan(plan, factors, backend=backend))
    times = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(mttkrp_from_plan(plan, factors, backend=backend))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of tuning one tensor band."""

    signature: BucketSignature
    backend: str
    best: TileConfig
    timings: Mapping[TileConfig, float]  # summed over tuned modes
    # Which modes the timings cover.  A partial-mode result is a valid
    # answer to the call that asked for it but NOT a valid band cache
    # entry: the band winner must rank configs on a full CP-ALS sweep's
    # worth of work, or a mode-0-only argmin silently serves every
    # future request for the band.
    modes: tuple[int, ...] = ()

    @property
    def best_s(self) -> float:
        return self.timings[self.best]

    @property
    def default_s(self) -> float:
        return self.timings[DEFAULT_TILE_CONFIG]

    @property
    def speedup_vs_default(self) -> float:
        return self.default_s / self.best_s

    def to_dict(self) -> dict:
        return {
            "signature": dataclasses.asdict(self.signature),
            "backend": self.backend,
            "modes": list(self.modes),
            "best": dataclasses.asdict(self.best),
            "best_s": self.best_s,
            "default_s": self.default_s,
            "speedup_vs_default": self.speedup_vs_default,
            "timings": {
                cfg.label: s for cfg, s in sorted(self.timings.items())
            },
        }


class Autotuner:
    """Per-tensor closed-loop tile tuner with band-keyed config caching.

    ``tune`` sweeps ``space.configs()`` over the tensor's modes with
    measured fenced medians on the resolved compiled backend and caches
    the argmin per geometry band; ``config_for`` answers from that cache
    (optionally tuning on miss) and is the duck-typed hook the serving
    layer's bucket geometry consumes.
    """

    def __init__(
        self,
        space: TuneSpace | None = None,
        *,
        backend: str | None = None,
        reps: int = 3,
        memo: WallTimeMemo | None = None,
        tune_on_miss: bool = False,
    ) -> None:
        from repro.kernels.mttkrp.ops import resolve_backend

        self.space = space or TuneSpace()
        self.backend = resolve_backend(backend)
        self.reps = reps
        self.memo = memo if memo is not None else WallTimeMemo()
        self.tune_on_miss = tune_on_miss
        self.results: dict[BucketSignature, TuneResult] = {}

    @staticmethod
    def signature_of(tensor, rank: int) -> BucketSignature:
        """The tuning-cache key: the serve layer's geometry band with
        ``n_iters=0`` (sweep count is irrelevant to kernel geometry)."""
        return geometry_signature(tensor.shape, tensor.nnz, rank, 0)

    def config_for(self, tensor, rank: int) -> TileConfig:
        """The cached winning config for the tensor's band (the serving
        hook).  Untuned bands answer the default config unless
        ``tune_on_miss`` — admission must stay cheap by default."""
        sig = self.signature_of(tensor, rank)
        result = self.results.get(sig)
        if result is not None:
            return result.best
        if self.tune_on_miss:
            return self.tune(tensor, rank).best
        return DEFAULT_TILE_CONFIG

    def tune(
        self,
        tensor,
        rank: int,
        *,
        modes: Sequence[int] | None = None,
        seed: int = 0,
        force: bool = False,
    ) -> TuneResult:
        """Measure every config on ``tensor`` and cache the band winner.

        Timings sum the per-mode fenced medians over ``modes`` (default:
        all modes — one CP-ALS sweep's worth of MTTKRP work).  Cells
        already measured for this band come from the ``WallTimeMemo``.

        Only full-mode results enter the band cache: a partial-mode
        argmin is an answer to this call, not to every future
        ``config_for`` in the band.  ``force=True`` re-measures — it
        bypasses both the result cache AND the wall-time memo (a forced
        re-tune that answers from stale measurements isn't a re-tune) and
        overwrites the memo cells with fresh numbers.
        """
        from repro.core.cp_als import cp_init

        sig = self.signature_of(tensor, rank)
        all_modes = tuple(range(tensor.nmodes))
        modes = all_modes if modes is None else tuple(int(m) for m in modes)
        covers_band = modes == all_modes
        if not force and covers_band and sig in self.results:
            return self.results[sig]
        factors = cp_init(tensor, rank, seed=seed)
        timings: dict[TileConfig, float] = {}
        for cfg in self.space.configs():
            total = 0.0
            for m in modes:
                key = self.memo.key(sig, m, cfg, self.backend, self.reps)
                s = None if force else self.memo.lookup(key)
                if s is None:
                    s = self.memo.store(
                        key,
                        measure_config(
                            tensor,
                            factors,
                            m,
                            cfg,
                            backend=self.backend,
                            reps=self.reps,
                        ),
                    )
                total += s
            timings[cfg] = total
        best = min(timings, key=lambda c: (timings[c], c != DEFAULT_TILE_CONFIG))
        result = TuneResult(
            signature=sig,
            backend=self.backend,
            best=best,
            timings=timings,
            modes=modes,
        )
        if covers_band:
            self.results[sig] = result
        return result


def measured_vs_modeled(
    tensor,
    result: TuneResult,
    *,
    rank: int,
    name: str = "autotuned",
    tech: MemoryTechSpec = O_SRAM,
    zipf_alpha: float = 0.75,
) -> list[dict]:
    """Price the tuner's measurements against the analytic DSE model.

    Each distinct ordering in the tune result becomes one ``SweepPoint``
    evaluated with the exact-trace hit-rate method over THIS tensor (an
    ad-hoc characteristics record carries its true dims/nnz), so every
    measured config gets the closed-form Eq-1 seconds the paper's model
    assigns to its execution order.  Modeled seconds move only with the
    ordering axis — the model has no concept of tile geometry, which is
    exactly why the measured column exists (DESIGN.md §13).
    """
    # math.prod over Python ints: np.prod would wrap to int64 (or go
    # negative) once the dense volume passes 2**63 — easily reached by
    # realistic FROSTT shapes (NELL-1 is ~2.4e6 x 2.1e6 x 2.5e7) — and a
    # negative volume turns density into garbage.
    volume = math.prod(int(d) for d in tensor.shape)
    chars = FrosttTensor(
        name=name,
        dims=tuple(int(d) for d in tensor.shape),
        nnz=int(tensor.nnz),
        density=float(tensor.nnz / max(1, volume)),
        zipf_alpha=zipf_alpha,
    )
    orderings = sorted({cfg.ordering for cfg in result.timings})
    points = [
        SweepPoint(label=f"{name}[ordering={o}]", tech=tech, rank=rank, ordering=o)
        for o in orderings
    ]
    sweep = evaluate_sweep(
        points,
        {name: chars},
        hit_rate_method="trace",
        trace_tensors={name: tensor},
        trace_nnz_limit=max(tensor.nnz, 1),
    )
    modeled = {
        o: sweep.cell(f"{name}[ordering={o}]", name).seconds for o in orderings
    }
    rows = []
    for cfg, measured_s in sorted(result.timings.items()):
        rows.append(
            {
                "config": cfg.label,
                "tile_nnz": cfg.tile_nnz,
                "rows_per_block": cfg.rows_per_block,
                "ordering": cfg.ordering,
                "measured_s": measured_s,
                "modeled_s": modeled[cfg.ordering],
                "best": cfg == result.best,
            }
        )
    return rows

"""zamba2-1.2b — hybrid: Mamba2 backbone + ONE shared attention block
applied periodically [arXiv:2411.15242]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # shared block is full MHA
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    shared_attn_every=6,
)

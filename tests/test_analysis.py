"""Tests for repro.analysis (DESIGN.md §15).

Each checker gets a true-positive + true-negative fixture pair under
``tests/analysis_fixtures/`` (laid out as a miniature repo so the
path-scoped checkers fire), the suppression and baseline mechanics are
exercised, the real repo must stay finding-clean, and the committed
Pallas write-only proof is asserted against the shipped kernels.
"""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import run_analysis
from repro.analysis.core import Finding, SourceFile, default_checkers

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"


def fixture_report(check_id: str | list[str], *relpaths: str):
    files = [SourceFile(FIXTURES / p, FIXTURES) for p in relpaths]
    checks = [check_id] if isinstance(check_id, str) else check_id
    return run_analysis(FIXTURES, checks=checks, files=files)


def messages(report) -> str:
    return "\n".join(f.message for f in report.findings)


# ---------------------------------------------------------------------------
# framework mechanics
# ---------------------------------------------------------------------------


def test_registry_has_the_contracted_checkers():
    ids = default_checkers()
    assert len(ids) >= 9
    for cid in (
        "pallas-kernel-contract",
        "trace-safety",
        "memo-key-completeness",
        "kwarg-threading",
        "shared-state-safety",
        "docs-citation",
        "grid-carry-init",
        "traffic-model-drift",
        "stale-suppression",
    ):
        assert cid in ids


def test_tests_tree_is_scanned_but_fixtures_are_waived():
    from repro.analysis.core import DEFAULT_SCAN_DIRS, is_fixture_path

    assert "tests" in DEFAULT_SCAN_DIRS
    assert is_fixture_path("tests/analysis_fixtures/src/repro/fx_trace_bad.py")
    assert not is_fixture_path("tests/test_kernels.py")


def test_fingerprint_is_line_independent():
    a = Finding("c", "p.py", 10, "msg")
    b = Finding("c", "p.py", 99, "msg")
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != Finding("c", "p.py", 10, "other").fingerprint


def test_suppression_waives_but_still_reports():
    report = fixture_report("kwarg-threading", "src/repro/fx_suppressed.py")
    assert len(report.findings) == 1
    assert report.findings[0].suppressed
    assert report.active == []


def test_unknown_check_id_rejected():
    with pytest.raises(ValueError, match="unknown check ids"):
        run_analysis(FIXTURES, checks=["no-such-check"], files=[])


# ---------------------------------------------------------------------------
# one TP/TN pair per checker
# ---------------------------------------------------------------------------


def test_pallas_contract_true_positive():
    report = fixture_report(
        "pallas-kernel-contract", "src/repro/kernels/fx/pallas_bad.py"
    )
    msgs = messages(report)
    assert "read-modify-written" in msgs
    assert "is read 1x" in msgs
    assert "stored 2x" in msgs
    assert "no short-circuiting 't == 0' test" in msgs
    assert "look-ahead load" in msgs
    assert "non-static shape element" in msgs
    assert len(report.active) == 6


def test_pallas_contract_true_negative():
    report = fixture_report(
        "pallas-kernel-contract", "src/repro/kernels/fx/pallas_good.py"
    )
    assert report.findings == []
    (kernel,) = report.facts["pallas-kernel-contract"]["kernels"]
    assert kernel["kernel"] == "good_kernel"
    assert kernel["out_refs"] == [
        {"name": "out_ref", "stores": 1, "aug_stores": 0, "reads": 0}
    ]
    assert kernel["carried_loads"] == kernel["guarded_loads"] == 2


def test_trace_safety_true_positive():
    report = fixture_report("trace-safety", "src/repro/fx_trace_bad.py")
    msgs = messages(report)
    assert "Python 'if' on a traced value" in msgs
    assert "float() on a traced value" in msgs
    assert "np.asarray" in msgs
    assert ".item() inside traced code" in msgs
    assert len(report.active) == 4


def test_trace_safety_true_negative():
    report = fixture_report("trace-safety", "src/repro/fx_trace_good.py")
    assert report.findings == []
    # the jitted function was actually audited, not skipped
    assert report.facts["trace-safety"]["traced_functions"] == 1


def test_memo_keys_true_positive():
    report = fixture_report("memo-key-completeness", "src/repro/fx_memo_bad.py")
    msgs = messages(report)
    assert "KEY_FIELDS omits field 'line_bytes'" in msgs
    assert "'stale_field'" in msgs
    assert "compare=False" in msgs
    assert "never uses it" in msgs  # the reps bug
    assert "asymmetric keys never hit" in msgs
    assert len(report.active) == 6  # put and get each flag the asymmetry


def test_memo_keys_true_negative():
    report = fixture_report("memo-key-completeness", "src/repro/fx_memo_good.py")
    assert report.findings == []
    facts = report.facts["memo-key-completeness"]
    assert facts["key_classes"] and facts["key_builders"] and facts["identity_caches"]


def test_kwarg_threading_true_positive():
    report = fixture_report("kwarg-threading", "src/repro/fx_kwarg_bad.py")
    assert len(report.active) == 1
    f = report.active[0]
    assert "'wrapper' accepts 'ordering'" in f.message
    assert "does not forward it" in f.message


def test_kwarg_threading_true_negative():
    report = fixture_report("kwarg-threading", "src/repro/fx_kwarg_good.py")
    assert report.findings == []
    # inner itself accepts watched knobs, so it is audited alongside the
    # three wrappers (its body just has no resolvable calls)
    assert report.facts["kwarg-threading"]["wrappers_audited"] == 4


def test_shared_state_true_positive():
    report = fixture_report(
        "shared-state-safety", "src/repro/serve/fx_shared_bad.py"
    )
    msgs = messages(report)
    assert "'_RESULTS' mutated at request time (item assignment)" in msgs
    assert "'_LOG' mutated at request time (.append())" in msgs
    assert len(report.active) == 2


def test_shared_state_true_negative():
    report = fixture_report(
        "shared-state-safety", "src/repro/serve/fx_shared_good.py"
    )
    assert report.findings == []
    containers = report.facts["shared-state-safety"]["containers"]
    # both the sanctioned cache and the import-time dict were audited
    assert containers == {"repro.serve.fx_shared_good": ["_AXES", "_CACHE"]}


def test_docs_citation_true_positive():
    report = fixture_report("docs-citation", "src/fx_docs_bad.py")
    assert len(report.active) == 1
    f = report.active[0]
    # (split so this literal is not itself picked up as a citation)
    assert "§99 cited but DESIGN" ".md has no matching heading" in f.message
    assert f.path == "src/fx_docs_bad.py" and f.line == 1


def test_docs_citation_true_negative():
    report = fixture_report("docs-citation", "src/fx_docs_good.py")
    assert report.findings == []
    assert report.facts["docs-citation"]["citations"] == 1


def test_grid_carry_init_true_positive():
    report = fixture_report(
        "grid-carry-init", "src/repro/kernels/fx/carry_bad.py"
    )
    msgs = messages(report)
    assert "without the t==0 wrap guard" in msgs
    assert "uninitialized garbage" in msgs
    assert len(report.active) == 5
    programs = report.facts["grid-carry-init"]["programs"]
    assert {p["program"] for p in programs} == {"uninit_call", "nowrap_call"}
    assert all(p["reads_proven"] == 0 for p in programs)


def test_grid_carry_init_true_negative():
    report = fixture_report(
        "grid-carry-init", "src/repro/kernels/fx/carry_good.py"
    )
    assert report.findings == []
    (program,) = report.facts["grid-carry-init"]["programs"]
    assert program["program"] == "carry_call"
    assert program["scratch_refs"] == ["acc_ref"]
    assert program["reads_proven"] == 2  # the interior += and the flush read


def test_traffic_drift_true_positive():
    report = fixture_report(
        "traffic-model-drift", "src/repro/kernels/fx/traffic_bad.py"
    )
    msgs = messages(report)
    assert "output stores drift" in msgs
    assert "2*I_mode*rank" in msgs
    assert len(report.active) == 2  # one per checked nmodes


def test_traffic_drift_true_negative():
    report = fixture_report(
        "traffic-model-drift", "src/repro/kernels/fx/traffic_good.py"
    )
    assert report.findings == []
    facts = report.facts["traffic-model-drift"]
    (census,) = facts["censuses"]
    assert census["program"] == "fx_stream_call"
    # 4 orderings x 3 modes on the replay tensor, all exact
    assert facts["replays_verified"] == 12


def test_stale_suppression_true_positive():
    report = fixture_report(
        ["kwarg-threading", "stale-suppression"], "src/repro/fx_stale.py"
    )
    assert len(report.active) == 1
    f = report.active[0]
    assert f.check_id == "stale-suppression"
    assert "matched no finding this run" in f.message
    assert report.facts["stale-suppression"] == {
        "suppressions_audited": 1,
        "stale": 1,
    }


def test_stale_suppression_true_negative():
    # fx_suppressed.py's waiver matches a real kwarg-threading finding,
    # so the audit must NOT flag it
    report = fixture_report(
        ["kwarg-threading", "stale-suppression"], "src/repro/fx_suppressed.py"
    )
    assert report.active == []
    assert len(report.suppressed) == 1
    assert report.facts["stale-suppression"] == {
        "suppressions_audited": 1,
        "stale": 0,
    }


def test_stale_suppression_only_judges_checks_that_ran():
    # kwarg-threading did not run, so its waiver is neither judged stale
    # nor counted as audited
    report = fixture_report(["stale-suppression"], "src/repro/fx_stale.py")
    assert report.findings == []
    assert report.facts["stale-suppression"] == {
        "suppressions_audited": 0,
        "stale": 0,
    }


def test_fingerprint_survives_line_shifts_in_the_fixture(tmp_path):
    """Inserting lines above a finding must not rotate its fingerprint
    (else every unrelated edit would invalidate the baseline)."""
    root = tmp_path / "mini"
    (root / "src").mkdir(parents=True)
    target = root / "src" / "wrap.py"
    target.write_text((FIXTURES / "src/repro/fx_kwarg_bad.py").read_text())

    before = run_analysis(root, checks=["kwarg-threading"])
    assert before.findings
    # edit the file in place: three pad lines shift every def downward
    target.write_text("# pad\n# pad\n# pad\n" + target.read_text())
    after = run_analysis(root, checks=["kwarg-threading"])

    assert [f.line for f in after.findings] != [f.line for f in before.findings]
    assert {f.fingerprint for f in after.findings} == {
        f.fingerprint for f in before.findings
    }


# ---------------------------------------------------------------------------
# the repo dogfoods its own gate
# ---------------------------------------------------------------------------


def test_repo_is_finding_clean():
    report = run_analysis(REPO)
    assert report.active == [], "\n".join(
        f"{f.location} [{f.check_id}] {f.message}" for f in report.active
    )
    # every waiver is a reviewed kwarg-threading suppression in measure.py
    for f in report.suppressed:
        assert f.check_id == "kwarg-threading"
        assert f.path == "src/repro/experiments/measure.py"


def test_repo_pallas_write_only_proof():
    report = run_analysis(REPO, checks=["pallas-kernel-contract"])
    kernels = {
        k["file"]: k for k in report.facts["pallas-kernel-contract"]["kernels"]
    }
    mttkrp = kernels["src/repro/kernels/mttkrp/kernel.py"]
    flash = kernels["src/repro/kernels/flash_attention/kernel.py"]
    for k in (mttkrp, flash):
        for ref in k["out_refs"]:
            assert ref["stores"] == 1, (k["file"], ref)
            assert ref["reads"] == 0 and ref["aug_stores"] == 0, (k["file"], ref)
    # the mttkrp streaming kernel's carried loads are all predicated
    assert mttkrp["carried_loads"] >= 2
    assert mttkrp["carried_loads"] == mttkrp["guarded_loads"]


def test_repo_grid_carry_proof():
    report = run_analysis(REPO, checks=["grid-carry-init"])
    assert report.active == []
    programs = {
        p["program"]: p for p in report.facts["grid-carry-init"]["programs"]
    }
    mttkrp = programs["mttkrp_pallas_call"]
    assert mttkrp["scratch_refs"] == ["acc_ref"]
    assert mttkrp["reads_proven"] == 2


def test_repo_traffic_drift_gate_is_zero_discrepancy():
    report = run_analysis(REPO, checks=["traffic-model-drift"])
    assert report.active == [], "\n".join(f.message for f in report.active)
    facts = report.facts["traffic-model-drift"]
    programs = {c["program"]: c for c in facts["censuses"]}
    assert set(programs) == {"mttkrp_pallas_call", "mttkrp_xla_call"}
    # both kernels x 4 orderings x 3 modes, every replay exact
    assert facts["replays_verified"] == 24
    # the flash-attention kernel is skipped with a recorded reason
    assert any(
        "flash_attention" in s["file"] for s in facts["skipped_programs"]
    )


def test_committed_report_matches_reality():
    committed = json.loads((REPO / "BENCH_analysis.json").read_text())
    assert committed["schema"] == "repro.analysis/v1"
    assert committed["totals"]["active"] == 0
    fresh = run_analysis(REPO)
    fresh_facts = fresh.to_dict()["facts"]
    assert fresh_facts["pallas-kernel-contract"] == (
        committed["facts"]["pallas-kernel-contract"]
    )
    # the symbolic traffic census rides in the committed report
    assert fresh_facts["traffic-model-drift"] == (
        committed["facts"]["traffic-model-drift"]
    )
    assert fresh_facts["grid-carry-init"] == committed["facts"]["grid-carry-init"]


def test_cli_gate_passes_on_the_repo():
    proc = subprocess.run(
        [
            sys.executable,
            str(REPO / "scripts" / "run_analysis.py"),
            "--baseline",
            str(REPO / "analysis_baseline.json"),
            "-q",
        ],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK: 0 new findings" in proc.stdout


def test_cli_baseline_tolerates_known_findings(tmp_path):
    # a finding fingerprinted in the baseline passes; a new one fails
    bad = FIXTURES / "src/repro/fx_kwarg_bad.py"
    root = tmp_path / "mini"
    (root / "src").mkdir(parents=True)
    (root / "src" / "wrap.py").write_text(bad.read_text())
    cli = [sys.executable, str(REPO / "scripts" / "run_analysis.py"),
           "--root", str(root), "--checks", "kwarg-threading"]

    proc = subprocess.run(cli + ["-q"], capture_output=True, text=True)
    assert proc.returncode == 1 and "new finding" in proc.stderr

    baseline = tmp_path / "baseline.json"
    subprocess.run(cli + ["--write-baseline", str(baseline)], check=True,
                   capture_output=True)
    proc = subprocess.run(cli + ["--baseline", str(baseline), "-q"],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_prune_baseline_drops_fixed_findings(tmp_path):
    bad = FIXTURES / "src/repro/fx_kwarg_bad.py"
    good = FIXTURES / "src/repro/fx_kwarg_good.py"
    root = tmp_path / "mini"
    (root / "src").mkdir(parents=True)
    target = root / "src" / "wrap.py"
    target.write_text(bad.read_text())
    baseline = tmp_path / "baseline.json"
    cli = [sys.executable, str(REPO / "scripts" / "run_analysis.py"),
           "--root", str(root), "--checks", "kwarg-threading"]

    subprocess.run(cli + ["--write-baseline", str(baseline)], check=True,
                   capture_output=True)
    assert json.loads(baseline.read_text())["fingerprints"]

    # the violation is fixed; pruning empties the baseline
    target.write_text(good.read_text())
    proc = subprocess.run(
        cli + ["--baseline", str(baseline), "--prune-baseline"],
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "pruned" in proc.stdout
    assert json.loads(baseline.read_text())["fingerprints"] == []


def test_cli_prune_baseline_requires_a_baseline(tmp_path):
    root = tmp_path / "mini"
    (root / "src").mkdir(parents=True)
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "run_analysis.py"),
         "--root", str(root), "--prune-baseline"],
        capture_output=True, text=True,
    )
    assert proc.returncode == 2
    assert "--baseline" in proc.stderr


def test_cli_changed_vs_narrows_the_scan(tmp_path):
    """--changed-vs scans only files changed against the git ref: a
    committed-and-unchanged violation is invisible, an untracked clean
    file keeps the gate green."""
    bad = (FIXTURES / "src/repro/fx_kwarg_bad.py").read_text()
    good = (FIXTURES / "src/repro/fx_kwarg_good.py").read_text()
    root = tmp_path / "mini"
    (root / "src").mkdir(parents=True)
    (root / "src" / "committed_bad.py").write_text(bad)
    git = ["git", "-C", str(root), "-c", "user.email=t@t", "-c", "user.name=t"]
    subprocess.run(git + ["init", "-q"], check=True, capture_output=True)
    subprocess.run(git + ["add", "-A"], check=True, capture_output=True)
    subprocess.run(git + ["commit", "-qm", "seed"], check=True,
                   capture_output=True)

    cli = [sys.executable, str(REPO / "scripts" / "run_analysis.py"),
           "--root", str(root), "--checks", "kwarg-threading",
           "--changed-vs", "HEAD"]

    # untracked clean file: scanned, no findings; the committed bad file
    # is unchanged and therefore not scanned at all
    (root / "src" / "new_good.py").write_text(good)
    proc = subprocess.run(cli + ["-q"], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr

    # touching the bad file brings it back into scope
    (root / "src" / "committed_bad.py").write_text(bad + "\n# touched\n")
    proc = subprocess.run(cli + ["-q"], capture_output=True, text=True)
    assert proc.returncode == 1
    assert "new finding" in proc.stderr


# ---------------------------------------------------------------------------
# dogfooded fix: mode_cost_analysis prices the measured geometry
# ---------------------------------------------------------------------------


def test_mode_cost_analysis_threads_measured_geometry(monkeypatch):
    """Regression: the HLO cost analysis must lower the *measured* plan.

    Before the kwarg-threading pass flagged it, ``mode_cost_analysis``
    built a default-geometry plan while ``measure_cp_als`` measured a
    custom ``tile_nnz``/``rows_per_block``/``ordering`` — flops/bytes
    could describe a different tile count and padding than the run."""
    import repro.experiments.measure as measure
    from repro.core.sparse_tensor import SparseTensor

    tensor = SparseTensor(
        indices=np.array([[0, 0, 0], [1, 1, 1], [2, 0, 1]], dtype=np.int32),
        values=np.ones(3, dtype=np.float32),
        shape=(3, 2, 2),
    )
    seen: dict = {}

    def recording_plan(t, mode, **kwargs):
        seen.update(kwargs)
        raise RuntimeError("stop after recording")

    monkeypatch.setattr(measure, "build_mttkrp_plan", recording_plan)
    flops, nbytes = measure.mode_cost_analysis(
        tensor, 2, 0, "pallas",
        tile_nnz=64, rows_per_block=32, ordering="degree",
    )
    assert (flops, nbytes) == (None, None)  # swallowed, as documented
    assert seen["tile_nnz"] == 64
    assert seen["rows_per_block"] == 32
    assert seen["ordering"] == "degree"

"""stale-suppression: ``# repro: ignore[...]`` must suppress something.

A suppression comment is a standing claim — "this line violates
check X, intentionally".  When the underlying code is fixed or the
checker sharpened, the comment outlives the finding and starts lying:
readers believe a contract is being violated where none is, and a NEW
violation introduced on that line later is silently absorbed by the
leftover comment.  This audit runs after every other selected checker
(``run_analysis`` orders it last) and flags each suppression entry that
matched no emitted finding this run.

Judgment is per check id and only for ids whose checker actually ran
(``ctx.checks_run``): a run restricted to ``--checks trace-safety``
must not condemn a ``kwarg-threading`` suppression it never exercised.
Fixture files are exempt — they violate contracts on purpose.  The
finding is itself suppressable (``# repro: ignore[stale-suppression]``)
for deliberately-kept tombstones.
"""

from __future__ import annotations

from repro.analysis.core import AnalysisContext, Checker, register


@register
class StaleSuppression(Checker):
    check_id = "stale-suppression"
    description = (
        "Every `# repro: ignore[check-id]` comment suppresses at least "
        "one finding of a checker that ran (audited last, per entry)"
    )

    def run(self, ctx: AnalysisContext) -> None:
        audited = 0
        stale = 0
        for sf in ctx.scannable():
            for lineno in sorted(sf.suppressions):
                for check_id in sorted(sf.suppressions[lineno]):
                    if check_id == self.check_id:
                        continue  # the audit's own tombstone marker
                    if check_id not in ctx.checks_run:
                        continue  # checker not exercised this run
                    audited += 1
                    # used_suppressions records the *comment's* line (the
                    # Checker.emit -> match_suppression contract).
                    if (lineno, check_id) in sf.used_suppressions:
                        continue
                    stale += 1
                    self.emit(
                        sf, lineno,
                        f"suppression `repro: ignore[{check_id}]` matched "
                        "no finding this run — the violation it excused is "
                        "gone; delete the comment (or it will silently "
                        "absorb the next real finding on this line)",
                    )
        self.facts["suppressions_audited"] = audited
        self.facts["stale"] = stale

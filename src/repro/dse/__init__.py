"""Design-space exploration over the paper's memory-technology model.

The paper's headline numbers (Fig 7 speedup, Fig 8 energy) are two points
in a larger design space — frequency, WDM wavelength count, port width,
cache geometry, PE count, DRAM channels, rank.  This package makes those
axes sweepable (DESIGN.md §8):

  * ``repro.dse.sweep``     — ``SweepSpec``/``SweepPoint``: grids of
    parameter overrides over the base ``MemoryTechSpec``/``TpuSpec`` /
    ``AcceleratorConfig`` / ``SystemConstants``, plus hierarchy-level
    axes (``level_axis_points``, ``add_level_point``,
    ``drop_level_point`` — DESIGN.md §9); the paper's E-SRAM vs O-SRAM
    comparison is the trivial 2-point sweep (``paper_pair``); the
    memory-controller knobs (``n_banks``, ``bank_policy``,
    ``prefetch_depth``, ``reorder_buffer``) are axes too, pricing
    points through the cycle-level simulator of
    ``repro.model.controller`` (DESIGN.md §14) — such points need
    ``trace_tensors=`` in the evaluator;
  * ``repro.dse.evaluator`` — resolves every point to its
    ``repro.core.hierarchy.MemoryHierarchy`` and prices all cells through
    the one batched engine, with hit rates memoized per ``CacheGeometry``
    (they never depend on the memory technology), choosing exact LRU
    trace simulation or the Che approximation per tensor;
  * ``repro.dse.pareto``    — the time-vs-energy comparison layer:
    Pareto frontier, ranking, and baseline-relative speedup/savings;
  * ``repro.dse.autotune``  — the measured side of the loop
    (DESIGN.md §13): closed-loop ``(tile_nnz, rows_per_block,
    ordering)`` tuning on the compiled MTTKRP backends, cached per
    serve-layer geometry band, priced measured-vs-modeled through
    ``evaluate_sweep``.

The TPU-v5e and photonic-IMC stacks participate as plain hierarchy
instances — no per-technology dispatch; sweep tables render through
``repro.perf.report``; ``benchmarks/dse_sweep.py`` is the CLI driver.
"""

from repro.dse.autotune import (
    DEFAULT_TILE_CONFIG,
    Autotuner,
    TileConfig,
    TuneResult,
    TuneSpace,
    WallTimeMemo,
    measure_config,
    measured_vs_modeled,
)
from repro.dse.evaluator import (
    HitRateCache,
    PointTensorResult,
    SweepResult,
    evaluate_sweep,
    exact_hit_rates,
    geometry_sim_config,
)
from repro.dse.pareto import (
    ParetoPoint,
    compare_techs,
    paper_pair_result,
    pareto_frontier,
    rank_configurations,
)
from repro.dse.sweep import (
    DEFAULT_AXIS_VALUES,
    SWEEP_AXES,
    SweepPoint,
    SweepSpec,
    add_level_point,
    drop_level_point,
    level_axis_points,
    paper_pair,
    tech_comparison,
)

__all__ = [
    "DEFAULT_TILE_CONFIG",
    "Autotuner",
    "TileConfig",
    "TuneResult",
    "TuneSpace",
    "WallTimeMemo",
    "measure_config",
    "measured_vs_modeled",
    "DEFAULT_AXIS_VALUES",
    "SWEEP_AXES",
    "SweepPoint",
    "SweepSpec",
    "add_level_point",
    "drop_level_point",
    "level_axis_points",
    "paper_pair",
    "tech_comparison",
    "HitRateCache",
    "PointTensorResult",
    "SweepResult",
    "evaluate_sweep",
    "exact_hit_rates",
    "geometry_sim_config",
    "ParetoPoint",
    "pareto_frontier",
    "rank_configurations",
    "compare_techs",
    "paper_pair_result",
]

#!/usr/bin/env python
"""Fail if any ``DESIGN.md §N`` citation lacks a matching DESIGN.md heading.

Thin wrapper kept for ``make docs-check`` compatibility: the check
itself lives in the ``docs-citation`` checker of ``repro.analysis``
(DESIGN.md §15), where it also runs under ``make analyze`` with
per-citation file:line findings.  This wrapper adds ``tests/`` to the
scan set (the analysis gate scans source dirs only) and keeps the old
exit semantics: nonzero iff any citation does not resolve.
"""

from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

SCAN_DIRS = ("src", "scripts", "tests", "benchmarks", "examples")


def main() -> int:
    from repro.analysis import run_analysis

    report = run_analysis(ROOT, checks=["docs-citation"], dirs=SCAN_DIRS)
    for f in report.active:
        print(f"docs-check: {f.location}: {f.message}", file=sys.stderr)
    if report.active:
        return 1
    facts = report.facts.get("docs-citation", {})
    cited = facts.get("sections_cited", [])
    print(
        f"docs-check: OK — {facts.get('citations', 0)} citations across "
        f"{len(cited)} sections ({', '.join('§' + s for s in cited)}), all resolve"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

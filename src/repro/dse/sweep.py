"""Sweepable design-space axes over the paper's configuration dataclasses.

A ``SweepSpec`` is a grid (cartesian product) of parameter overrides
applied on top of a base configuration (``MemoryTechSpec`` +
``AcceleratorConfig``/``CacheConfig`` + ``SystemConstants`` + rank).  Each
grid cell materializes as a frozen ``SweepPoint`` — a fully-resolved
configuration the evaluator can price (DESIGN.md §8).

Axes are named in ``SWEEP_AXES``; each maps to a (layer, field) pair and
is applied with ``dataclasses.replace`` so the base specs stay immutable.
The paper's own E-SRAM/O-SRAM comparison is the trivial two-point sweep
returned by ``paper_pair``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Mapping, Sequence

from repro.core.accelerator import PAPER_ACCEL, AcceleratorConfig
from repro.core.cache_sim import CacheConfig
from repro.core.memory_tech import (
    E_SRAM,
    O_SRAM,
    PAPER_SYSTEM,
    MemoryTechSpec,
    SystemConstants,
    TpuSpec,
)
from repro.data.frostt import PAPER_RANK

__all__ = [
    "SWEEP_AXES",
    "DEFAULT_AXIS_VALUES",
    "SweepPoint",
    "SweepSpec",
    "paper_pair",
    "tech_comparison",
]


# axis name -> (layer, dataclass field).  Layers: "tech" (MemoryTechSpec),
# "cache" (AcceleratorConfig.cache), "accel" (AcceleratorConfig),
# "system" (SystemConstants), "run" (evaluation parameters, i.e. rank).
SWEEP_AXES: dict[str, tuple[str, str]] = {
    "frequency": ("tech", "frequency_hz"),
    "wavelengths": ("tech", "wavelengths"),
    "port_width": ("tech", "port_width_bits"),
    "ports_per_block": ("tech", "ports_per_block"),
    "cache_lines": ("cache", "num_lines"),
    "line_bytes": ("cache", "line_bytes"),
    "associativity": ("cache", "associativity"),
    "n_caches": ("accel", "n_caches"),
    "n_pe": ("accel", "n_pe"),
    "pipelines": ("accel", "pipelines_per_pe"),
    "dram_channels": ("system", "dram_channels"),
    "f_electrical": ("system", "f_electrical"),
    "rank": ("run", "rank"),
}

# Default value grids used by benchmarks/dse_sweep.py when the caller
# names an axis without giving explicit values.  Base-point values are
# included so every sweep contains the paper configuration itself.
DEFAULT_AXIS_VALUES: dict[str, tuple[Any, ...]] = {
    "frequency": (1e9, 5e9, 10e9, 20e9, 40e9),
    "wavelengths": (1, 2, 4, 5, 8, 16),
    "port_width": (16, 32, 64),
    "ports_per_block": (1, 2, 4),
    "cache_lines": (1024, 2048, 4096, 8192, 16384),
    "line_bytes": (32, 64, 128),
    "associativity": (1, 2, 4, 8),
    "n_caches": (1, 3, 6),
    "n_pe": (2, 4, 8),
    "pipelines": (40, 80, 160),
    "dram_channels": (2, 4, 8),
    "f_electrical": (250e6, 500e6, 1e9),
    "rank": (8, 16, 32),
}


def _fmt_value(v: Any) -> str:
    if isinstance(v, float) and v >= 1e6:
        return f"{v/1e9:g}GHz" if v >= 1e9 else f"{v/1e6:g}MHz"
    return f"{v:g}" if isinstance(v, float) else str(v)


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One fully-resolved configuration of the design space.

    ``tech`` is a ``MemoryTechSpec`` (FPGA memory technologies) or a
    ``TpuSpec`` — the evaluator dispatches on the type so a TPU-v5e-class
    chip participates as a third technology via the roofline engine.
    """

    label: str
    tech: MemoryTechSpec | TpuSpec
    accel: AcceleratorConfig = PAPER_ACCEL
    system: SystemConstants = PAPER_SYSTEM
    rank: int = PAPER_RANK
    overrides: tuple[tuple[str, Any], ...] = ()

    @property
    def is_tpu(self) -> bool:
        return isinstance(self.tech, TpuSpec)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Grid of overrides over a base configuration.

    ``axes`` maps axis names (keys of ``SWEEP_AXES``) to value sequences;
    ``points()`` yields the cartesian product.  Axis order follows the
    mapping's insertion order, so the first axis varies slowest.
    """

    axes: Mapping[str, Sequence[Any]]
    base_tech: MemoryTechSpec = O_SRAM
    base_accel: AcceleratorConfig = PAPER_ACCEL
    base_system: SystemConstants = PAPER_SYSTEM
    rank: int = PAPER_RANK

    def __post_init__(self):
        unknown = [a for a in self.axes if a not in SWEEP_AXES]
        if unknown:
            raise ValueError(
                f"unknown sweep axes {unknown}; known: {sorted(SWEEP_AXES)}"
            )

    def num_points(self) -> int:
        n = 1
        for vals in self.axes.values():
            n *= len(vals)
        return n

    def points(self) -> list[SweepPoint]:
        names = list(self.axes)
        out = []
        for combo in itertools.product(*(self.axes[a] for a in names)):
            overrides = tuple(zip(names, combo))
            tech, accel, system, rank = self._apply(overrides)
            label = f"{self.base_tech.name}[" + ",".join(
                f"{a}={_fmt_value(v)}" for a, v in overrides
            ) + "]"
            out.append(
                SweepPoint(
                    label=label,
                    tech=tech,
                    accel=accel,
                    system=system,
                    rank=rank,
                    overrides=overrides,
                )
            )
        return out

    def _apply(
        self, overrides: tuple[tuple[str, Any], ...]
    ) -> tuple[MemoryTechSpec, AcceleratorConfig, SystemConstants, int]:
        tech_kw: dict[str, Any] = {}
        cache_kw: dict[str, Any] = {}
        accel_kw: dict[str, Any] = {}
        system_kw: dict[str, Any] = {}
        rank = self.rank
        for axis, value in overrides:
            layer, field = SWEEP_AXES[axis]
            if layer == "tech":
                tech_kw[field] = value
            elif layer == "cache":
                cache_kw[field] = value
            elif layer == "accel":
                accel_kw[field] = value
            elif layer == "system":
                system_kw[field] = value
            else:  # run
                rank = int(value)
        tech = dataclasses.replace(self.base_tech, **tech_kw) if tech_kw else self.base_tech
        accel = self.base_accel
        if cache_kw:
            accel_kw["cache"] = dataclasses.replace(accel.cache, **cache_kw)
        if accel_kw:
            accel = dataclasses.replace(accel, **accel_kw)
        system = (
            dataclasses.replace(self.base_system, **system_kw)
            if system_kw
            else self.base_system
        )
        return tech, accel, system, rank


def paper_pair(
    *,
    accel: AcceleratorConfig = PAPER_ACCEL,
    system: SystemConstants = PAPER_SYSTEM,
    rank: int = PAPER_RANK,
) -> list[SweepPoint]:
    """The paper's E-SRAM/O-SRAM comparison as the trivial 2-point sweep."""
    return [
        SweepPoint(label=E_SRAM.name, tech=E_SRAM, accel=accel, system=system, rank=rank),
        SweepPoint(label=O_SRAM.name, tech=O_SRAM, accel=accel, system=system, rank=rank),
    ]


def tech_comparison(
    techs: Sequence[MemoryTechSpec | TpuSpec],
    *,
    accel: AcceleratorConfig = PAPER_ACCEL,
    system: SystemConstants = PAPER_SYSTEM,
    rank: int = PAPER_RANK,
) -> list[SweepPoint]:
    """A list-sweep over arbitrary technology specs (incl. ``TpuSpec``)."""
    return [
        SweepPoint(label=t.name, tech=t, accel=accel, system=system, rank=rank)
        for t in techs
    ]
